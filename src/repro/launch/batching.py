"""Continuous-batching request scheduler for the serving path.

A production-shaped serving loop: requests arrive with different prompt
lengths and generation budgets; the scheduler packs up to ``max_batch``
active sequences into one fixed-shape decode batch (padded slots), admits
new requests as slots free up, and steps them together through
``Model.decode_step`` — each slot at its OWN position.  Fixed shapes keep a
single compiled executable; per-slot positions enter the model as a (B,)
vector (batched RoPE, per-slot cache row, per-slot visibility mask), so a
freshly-admitted request streams its prompt while its neighbors generate,
and every slot's token stream is bitwise the one sequential ``generate``
would produce (tests/test_batching.py pins this).

The host-side slot state machine lives in ``SlotScheduler`` so the fleet
driver (``launch/fleet.py``) can run one scheduler per replica while all
replicas share ONE jitted step function (``make_batched_step``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jax.Array          # (P,) int32 (numpy or jax; host-indexed)
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # serving-trace bookkeeping (filled by the fleet driver)
    arrive_round: int = 0
    done_round: int = -1
    admit_round: int = -1      # round a slot last accepted this request
    first_token_round: int = -1  # round the first surviving token landed
    restarts: int = 0          # times re-admitted after a churn kill


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0               # tokens fed so far == next cache position
    prompt_cursor: int = 0     # how much of the prompt has been fed
    generated: int = 0


class SlotScheduler:
    """Host-side slot state machine: admission, token staging, absorption.

    Device-free — ``prepare()`` emits plain Python lists the driver turns
    into one fixed-shape batch, ``absorb()`` folds the decoded tokens back.
    Invariants (tests/test_batching.py): every submitted request finishes
    exactly once with exactly ``max_new`` tokens (unless evicted), under
    any interleaving of submissions and steps.
    """

    def __init__(self, max_batch: int, max_len: int):
        self.max_batch = max_batch
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(max_batch)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []

    # ------------------------------------------------------------ frontend
    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new + 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} needs {need} cache rows but max_len="
                f"{self.max_len} — the slot would silently truncate below "
                "the guaranteed max_new tokens (mirrors the GossipFleet "
                "ServeLoad range check)")
        self.queue.append(req)

    def load(self) -> int:
        """Queued + in-flight requests (the fleet router's balance key)."""
        return len(self.queue) + sum(s.req is not None for s in self.slots)

    def pending(self) -> bool:
        return bool(self.queue) or any(s.req is not None for s in self.slots)

    # ------------------------------------------------------------ stepping
    def _admit(self, round_idx: int = 0) -> None:
        for slot in self.slots:
            if slot.req is None and self.queue:
                slot.req = self.queue.popleft()
                slot.req.admit_round = round_idx
                slot.pos = 0
                slot.prompt_cursor = 0
                slot.generated = 0

    def prepare(self, round_idx: int = 0
                ) -> tuple[list[int], list[int], list[bool]]:
        """Admit waiting requests, then stage one token per active slot.

        Returns (tokens, positions, active) as length-``max_batch`` lists:
        slot i feeds ``tokens[i]`` at cache position ``positions[i]``.
        A slot still streaming its prompt feeds the next prompt token; a
        generating slot feeds its last output token.  ``round_idx`` stamps
        ``admit_round`` on newly-admitted requests (TTFT bookkeeping).
        """
        self._admit(round_idx)
        toks, pos, act = [], [], []
        for s in self.slots:
            r = s.req
            if r is None:
                toks.append(0)
                pos.append(0)
                act.append(False)
                continue
            if s.prompt_cursor < len(r.prompt):
                toks.append(int(r.prompt[s.prompt_cursor]))
            else:
                toks.append(int(r.out[-1]) if r.out else 0)
            pos.append(s.pos)
            act.append(True)
        return toks, pos, act

    def absorb(self, next_tokens: np.ndarray, round_idx: int = 0
               ) -> list[Request]:
        """Fold one decode step's outputs back into the slots; returns the
        requests that completed this step.  The token produced when the
        LAST prompt token is fed is the first generated token — exactly
        ``generate``'s sampling point."""
        done: list[Request] = []
        for i, s in enumerate(self.slots):
            r = s.req
            if r is None:
                continue
            s.pos += 1
            if s.prompt_cursor < len(r.prompt) - 1:
                s.prompt_cursor += 1          # still streaming the prompt
            else:
                if s.prompt_cursor == len(r.prompt) - 1:
                    s.prompt_cursor += 1      # prompt consumed this step
                r.out.append(int(next_tokens[i]))
                if len(r.out) == 1:
                    r.first_token_round = round_idx
                s.generated += 1
            if s.generated >= r.max_new or s.pos >= self.max_len - 1:
                r.done = True
                r.done_round = round_idx
                self.finished.append(r)
                done.append(r)
                s.req = None
        return done

    # --------------------------------------------------------------- churn
    def evict_all(self) -> list[Request]:
        """Kill this replica: return every queued AND in-flight request for
        re-admission elsewhere.  In-flight requests restart from scratch
        (their cache rows die with the replica): outputs are cleared and
        ``restarts`` is bumped — degradation, not loss."""
        out: list[Request] = []
        for s in self.slots:
            if s.req is not None:
                s.req.out = []
                s.req.restarts += 1
                # TTFT restarts with the request: the first token died
                # with the replica's KV rows
                s.req.admit_round = -1
                s.req.first_token_round = -1
                out.append(s.req)
                s.req = None
        out.extend(self.queue)
        self.queue.clear()
        return out


def gate_caches(active, old, new):
    """Keep inactive slots' cache state untouched after a decode step.

    ``decode_step`` writes every slot's cache unconditionally, so a slot
    fed padding (token 0 at position 0) would overwrite cache position 0 —
    exactly where an in-flight request's first K/V row lives — and advance
    the recurrent ssd/rglru states.  The fleet driver feeds WHOLE replicas
    as padding while they stall on communication debt, so this gating is
    load-bearing.  Cache leaves are (repeat, B, ...): batch is axis 1.
    """
    def sel(o, n):
        return jnp.where(active.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o)

    return jax.tree.map(sel, old, new)


def make_batched_step(model: Model) -> Callable:
    """One jit-able greedy decode step over a slot batch.

    (params, caches, tokens (B,1) i32, positions (B,) i32, active (B,) bool)
    -> (next_tokens (B,) i32, new caches).  Shared across replicas in the
    fleet driver so W schedulers ride one compiled executable.
    """
    V = model.cfg.vocab_size

    def step(params, caches, tokens, positions, active):
        logits, new_caches = model.decode_step(params, tokens, positions,
                                               caches)
        nxt = jnp.argmax(logits[:, 0, :V], axis=-1)
        return (jnp.where(active, nxt, 0).astype(jnp.int32),
                gate_caches(active, caches, new_caches))

    return step


class ContinuousBatcher:
    """Slot-based continuous batching over the decode path (one replica).

    ``step_fn`` lets callers share one jitted step across batchers; by
    default each batcher compiles its own.
    """

    def __init__(self, model: Model, params, max_batch: int = 4,
                 max_len: int = 512, step_fn: Callable | None = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.caches = model.init_cache(max_batch, max_len)
        self.scheduler = SlotScheduler(max_batch, max_len)
        self._step = step_fn if step_fn is not None \
            else jax.jit(make_batched_step(model))

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    @property
    def finished(self) -> list[Request]:
        return self.scheduler.finished

    def step(self) -> int:
        """Advance every active slot by one token; returns #active slots."""
        toks, pos, act = self.scheduler.prepare()
        n_active = sum(act)
        if not n_active:
            return 0
        nxt, self.caches = self._step(
            self.params, self.caches,
            jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32), jnp.asarray(act))
        self.scheduler.absorb(jax.device_get(nxt))
        return n_active

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.scheduler.queue:
                break
        return self.scheduler.finished
