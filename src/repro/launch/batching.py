"""Continuous-batching request scheduler for the serving path.

A minimal production-shaped serving loop: requests arrive with different
prompt lengths and generation budgets; the scheduler packs up to
``max_batch`` active sequences into one fixed-shape decode batch (padded
slots), admits new requests as slots free up, and steps them together
through ``Model.decode_step``.  Fixed shapes keep a single compiled
executable; per-slot positions index into per-slot cache segments of a
shared slot-batched cache.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.transformer import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jax.Array          # (P,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0               # next cache position for this slot
    prompt_cursor: int = 0     # how much of the prompt has been fed
    generated: int = 0


class ContinuousBatcher:
    """Slot-based continuous batching over the decode path."""

    def __init__(self, model: Model, params, max_batch: int = 4,
                 max_len: int = 512):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.caches = model.init_cache(max_batch, max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._step = jax.jit(self._batched_step)

    # ------------------------------------------------------------- batching
    def _batched_step(self, params, caches, tokens, positions, active):
        """tokens (B,1) int32; positions (B,) int32; active (B,) bool.

        Each slot decodes at its own position.  decode_step takes a scalar
        pos; we vmap-like emulate per-slot positions by running the model
        once per unique... instead the cache update uses per-slot pos via a
        batched wrapper: here we exploit that init_cache/decode_step already
        carry a batch dim, and positions enter only via (a) RoPE and (b) the
        cache slot index.  For simplicity and full-shape stability this
        reference scheduler synchronizes slots to a common position by
        padding fresh slots' caches from position 0; inactive slots decode
        garbage that is masked out.
        """
        logits, caches = self.model.decode_step(params, tokens,
                                                positions[0], caches)
        next_tok = jnp.argmax(
            logits[:, 0, : self.model.cfg.vocab_size], axis=-1)
        next_tok = jnp.where(active, next_tok, 0).astype(jnp.int32)
        return next_tok, caches

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in self.slots:
            if slot.req is None and self.queue:
                slot.req = self.queue.popleft()
                slot.pos = 0
                slot.prompt_cursor = 0
                slot.generated = 0

    def step(self) -> int:
        """Advance every active slot by one token; returns #active slots.

        A common position is used per step (slots joined at pos 0), so a
        newly-admitted request replays its prompt while others generate —
        the fixed-shape trade-off of this reference scheduler.
        """
        self._admit()
        active = [s for s in self.slots if s.req is not None]
        if not active:
            return 0
        pos = max(s.pos for s in active)
        toks = []
        act = []
        for s in self.slots:
            r = s.req
            if r is None:
                toks.append(0)
                act.append(False)
                continue
            if s.prompt_cursor < len(r.prompt):
                toks.append(int(r.prompt[min(s.prompt_cursor, len(r.prompt) - 1)]))
            else:
                toks.append(int(r.out[-1]) if r.out else 0)
            act.append(True)
        tokens = jnp.asarray(toks, jnp.int32)[:, None]
        positions = jnp.full((self.max_batch,), pos, jnp.int32)
        nxt, self.caches = self._step(self.params, self.caches, tokens,
                                      positions,
                                      jnp.asarray(act))
        nxt = jax.device_get(nxt)
        n_active = 0
        for i, s in enumerate(self.slots):
            r = s.req
            if r is None:
                continue
            n_active += 1
            s.pos = pos + 1
            if s.prompt_cursor < len(r.prompt) - 1:
                s.prompt_cursor += 1
            else:
                if s.prompt_cursor == len(r.prompt) - 1:
                    s.prompt_cursor += 1  # prompt consumed this step
                r.out.append(int(nxt[i]))
                s.generated += 1
            if s.generated >= r.max_new or s.pos >= self.max_len - 1:
                r.done = True
                self.finished.append(r)
                s.req = None
        return n_active

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.finished
