import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

# Multi-pod dry-run: lower + compile every (arch x shape) on the production
# meshes, print memory/cost analysis, and emit roofline terms.
#
# MUST be the process entrypoint (jax locks the device count on first init):
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
#         --shape train_4k --mesh single
#     PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
#
# The two os.environ lines above run before ANY other import by design.

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np


def run_one(arch: str, shape_name: str, mesh_name: str,
            serve_param_mode: str = "fsdp",
            train_microbatches: int = 4,
            carry_shard: str = None) -> dict:
    from repro.analysis.roofline import model_flops, roofline_terms
    from repro.configs import get_config
    from repro.launch.mesh import make_gossip_mesh, make_production_mesh, rules_for
    from repro.launch.steps import bundle_for
    from repro.models.transformer import Model
    from repro.shapes import adapt_config, shape_for

    t0 = time.time()
    if mesh_name == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif mesh_name == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif mesh_name == "gossip":
        mesh = make_gossip_mesh()
    else:
        raise ValueError(mesh_name)
    rules = rules_for(mesh)

    cfg = get_config(arch).with_updates(param_dtype="bfloat16",
                                        compute_dtype="bfloat16")
    if carry_shard:
        cfg = cfg.with_updates(carry_shard=carry_shard)
    shape = shape_for(shape_name)
    spec = bundle_for(cfg, shape, mesh, rules,
                      train_microbatches=train_microbatches,
                      serve_param_mode=serve_param_mode)
    with mesh:
        lowered = spec.lower(mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # XLA's cost_analysis counts while bodies once (ignores trip counts) —
    # our HLO-text cost model multiplies scan bodies by their trip counts.
    from repro.analysis.hlo_cost import cost_from_hlo
    hc = cost_from_hlo(hlo)

    acfg = adapt_config(cfg, shape)
    model = Model(acfg)
    pcounts = _param_counts(model)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    kind = "train" if shape.kind == "train" else "serve"
    mf = model_flops(pcounts["total"], pcounts["active"], tokens, kind)

    peak = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0) + \
        getattr(mem, "output_size_in_bytes", 0) - \
        getattr(mem, "alias_size_in_bytes", 0)
    report = roofline_terms(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=mesh.devices.size,
        cost={"flops": hc.flops, "bytes accessed": hc.write_bytes},
        hlo_text="", model_flops_total=mf, peak_memory=float(peak))
    report = dataclasses.replace(
        report, collective_bytes=float(hc.collective_bytes),
        collective_detail=hc.collective_detail)
    out = report.to_dict()
    out.update({
        "ok": True,
        "fits_v5e_hbm": bool(peak <= 16e9),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "param_count": pcounts["total"], "active_params": pcounts["active"],
        "xla_cost_analysis_flops": float(dict(cost).get("flops", 0.0))
        if cost else 0.0,
        "memory_analysis": str(mem),
    })
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
          f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
          f"peak/device {peak/1e9:.2f} GB, bottleneck {out['bottleneck']})")
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis: flops/device={out['hlo_flops_per_device']:.3e} "
          f"bytes/device={out['hlo_bytes_per_device']:.3e} "
          f"collective/device={out['collective_bytes_per_device']:.3e}")
    return out


def _param_counts(model) -> dict:
    """Total and *active* (per-token) parameter counts, analytic."""
    cfg = model.cfg
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    active = total
    if cfg.moe is not None:
        # routed experts contribute top_k/num_experts of their weights
        def leaf_count(path, leaf):
            return int(np.prod(leaf.shape))
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        routed = sum(int(np.prod(l.shape)) for p, l in flat
                     if "moe_" in _path(p) and l.ndim >= 3)
        active = total - routed + int(routed * cfg.moe.top_k
                                      / cfg.moe.num_experts)
    return {"total": total, "active": active}


def _path(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)


ALL_MESHES = ("single", "multi")


def run_gossip_step(arch: str = "qwen3-0.6b", n_workers: int = 8,
                    accelerated: bool = True, mode: str = "gossip",
                    comms_per_step: int = 1) -> dict:
    """Lower + compile the decentralized A2CiD2 train step on the gossip
    mesh (8 workers x 8 data x 8 model = 512 chips, ring graph).

    Uses the stacked (pjit-native) trainer: state leaves carry a leading
    worker axis sharded over "worker"; gossip is a gather along it, which
    XLA lowers to collective-permute."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import sharding as shardlib
    from repro.analysis.hlo_cost import cost_from_hlo
    from repro.configs import get_config
    from repro.core import params_from_graph, ring_graph
    from repro.launch import shardings as S
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.launch.mesh import make_gossip_mesh, rules_for
    from repro.models.transformer import Model
    from repro.optim import sgd

    t0 = time.time()
    mesh = make_gossip_mesh(n_workers=n_workers)
    rules = rules_for(mesh)
    cfg = get_config(arch).with_updates(param_dtype="bfloat16",
                                        compute_dtype="bfloat16")
    model = Model(cfg)
    graph = ring_graph(n_workers)
    acid = params_from_graph(graph, accelerated=accelerated)

    def grad_fn(params, batch):
        def loss_fn(p):
            loss, _ = model.loss(p, batch, remat=True)
            return loss, None
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    trainer = StackedGossipTrainer(
        grad_fn, sgd(), graph, acid, lr=0.1,
        comms_per_step=(0 if mode == "grad_only" else comms_per_step))
    step = {"ar": trainer.make_ar_step,
            "pair_ring": trainer.make_pair_ring_step}.get(
        mode, trainer.make_step)()

    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    state = jax.eval_shape(
        lambda: trainer.init(
            jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), params),
            jax.random.PRNGKey(0)))
    B, Sq = 256 // n_workers, 4096  # per-worker slice of train_4k
    batch = {"inputs": jax.ShapeDtypeStruct((n_workers, B, Sq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((n_workers, B, Sq), jnp.int32)}

    psh = S.stacked_param_shardings(state.x, mesh, rules)
    state_sh = state._replace(
        x=psh, x_tilde=psh,
        opt=type(state.opt)(NamedSharding(mesh, P("worker")), psh, None),
        key=NamedSharding(mesh, P()))
    batch_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P("worker", "data", None)), batch)

    with shardlib.use_mesh(mesh, rules):
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          donate_argnums=(0,)).lower(state, batch)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hc = cost_from_hlo(compiled.as_text())
    peak = (getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0))
    out = {
        "ok": True, "arch": arch, "shape": "train_4k", "mesh": "gossip",
        "accelerated": accelerated,
        "n_workers": n_workers, "chips": int(mesh.devices.size),
        "peak_memory_per_device": float(peak),
        "fits_v5e_hbm": bool(peak <= 16e9),
        "hlo_flops_per_device": hc.flops,
        "hlo_bytes_per_device": hc.write_bytes,
        "collective_bytes_per_device": hc.collective_bytes,
        "collective_detail": hc.collective_detail,
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": str(mem),
    }
    out["mode"] = mode
    out["comms_per_step"] = comms_per_step
    tag = mode if mode != "gossip" else ("A2CiD2" if accelerated
                                         else "baseline")
    print(f"[dryrun] gossip({tag}) {arch} x train_4k x (8,8,8): OK "
          f"(total {out[chr(39)+'compile_s'+chr(39)] if False else out['compile_s']}s, peak/device {peak/1e9:.2f} GB, "
          f"collective/device {hc.collective_bytes/1e9:.1f} GB)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=("single", "multi", "gossip"))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on --mesh")
    ap.add_argument("--out", type=str, default=None,
                    help="append JSON results to this file")
    ap.add_argument("--serve-param-mode", default="fsdp",
                    choices=("fsdp", "tp_only"))
    ap.add_argument("--train-microbatches", type=int, default=4)
    ap.add_argument("--carry-shard", default=None,
                    choices=(None, "embed", "seq", "none"))
    args = ap.parse_args()

    if args.mesh == "gossip":
        a = args.arch or "qwen3-0.6b"
        results = [run_gossip_step(a, accelerated=True),
                   run_gossip_step(a, accelerated=False),
                   run_gossip_step(a, mode="grad_only"),
                   run_gossip_step(a, mode="ar"),
                   run_gossip_step(a, accelerated=True, comms_per_step=2),
                   run_gossip_step(a, mode="pair_ring")]
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        return

    from repro.configs import ARCHITECTURES
    from repro.shapes import SHAPES

    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in ARCHITECTURES for s in SHAPES])

    results = []
    for arch, shape in combos:
        try:
            results.append(run_one(
                arch, shape, args.mesh,
                serve_param_mode=args.serve_param_mode,
                train_microbatches=args.train_microbatches,
                carry_shard=args.carry_shard))
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape, "mesh": args.mesh,
                            "ok": False, "error": f"{type(e).__name__}: {e}"})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} combos OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
