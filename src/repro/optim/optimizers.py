"""Optimizers as (init, update) pairs on pytrees (optax-style, no optax dep).

The paper trains with SGD + heavy-ball momentum 0.9 + weight decay 5e-4
(decoupled from the learnable norm scales, following Goyal et al.) — `sgd`
reproduces that.  `adamw` is provided for the LM substrate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree          # momentum / first moment
    nu: PyTree | None   # second moment (adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree, jax.Array],
                     tuple[PyTree, OptState]]


def _tree_zeros(params):
    return jax.tree.map(jnp.zeros_like, params)


def _is_norm_scale(path: tuple) -> bool:
    """Heuristic: 1-D leaves named *norm*/scale/bias are exempt from weight
    decay (paper Sec 4.1, following [16])."""
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    joined = "/".join(str(n) for n in names).lower()
    return any(t in joined for t in ("norm", "gn", "bias", "b_a", "b_x",
                                     "lam", "dt_bias", "a_log", "slot_pos"))


def sgd(momentum: float = 0.9, weight_decay: float = 5e-4,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _tree_zeros(params), None)

    def update(grads, state, params, lr):
        paths = jax.tree_util.tree_flatten_with_path(params)[0]
        wd_mask = [0.0 if _is_norm_scale(p) else 1.0 for p, _ in paths]
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_mu = treedef.flatten_up_to(state.mu)
        new_mu, new_p = [], []
        for g, p, mu, m in zip(flat_g, flat_p, flat_mu, wd_mask):
            # all update math in the param dtype: f32 upcasts of the large
            # stacked params materialize 2x-param-size f32 buffers at the
            # optimizer step (the lr scalar is cast, not the tensors)
            dt = p.dtype
            g = g.astype(dt) + (weight_decay * m) * p
            mu = momentum * mu.astype(dt) + g
            d = (g + momentum * mu) if nesterov else mu
            new_mu.append(mu)
            new_p.append(p - lr.astype(dt) * d)
        return (treedef.unflatten(new_p),
                OptState(state.step + 1, treedef.unflatten(new_mu), None))

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        # fp32 moments regardless of param dtype (mixed-precision master stats)
        zeros32 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros32,
                        jax.tree.map(jnp.copy, zeros32))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        paths = jax.tree_util.tree_flatten_with_path(params)[0]
        wd_mask = [0.0 if _is_norm_scale(p) else 1.0 for p, _ in paths]
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        new_mu, new_nu, new_p = [], [], []
        for g, p, mu, nu, m in zip(flat_g, flat_p, flat_mu, flat_nu, wd_mask):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * g32 * g32
            upd = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            upd = upd + weight_decay * m * p.astype(jnp.float32)
            new_mu.append(mu)
            new_nu.append(nu)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        return (treedef.unflatten(new_p),
                OptState(step, treedef.unflatten(new_mu),
                         treedef.unflatten(new_nu)))

    return Optimizer(init, update)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    # f32 accumulation without materializing f32 copies of the (large) grads
    norm = jnp.sqrt(sum(jnp.sum(g * g, dtype=jnp.float32)
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)
