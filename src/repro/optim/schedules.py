"""Learning-rate schedules.

`goyal_warmup_step_decay` is the paper's schedule (Sec 4.1): linear warmup
scaling the base LR by the worker count (large-batch rule of Goyal et al.
[16]) followed by x0.1 step decays at fixed epoch milestones.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def goyal_warmup_step_decay(base_lr: float, n_workers: int,
                            steps_per_epoch: int,
                            milestones: Sequence[int] = (30, 60, 80),
                            warmup_epochs: int = 5,
                            total_epochs: int = 90) -> Schedule:
    """LR = base * n_workers after warmup; /10 at each milestone epoch."""
    peak = base_lr * n_workers
    warm = warmup_epochs * steps_per_epoch

    def sched(step):
        step = step.astype(jnp.float32)
        warm_lr = base_lr + (peak - base_lr) * jnp.minimum(step / warm, 1.0)
        decay = jnp.ones(())
        for m in milestones:
            decay = decay * jnp.where(step >= m * steps_per_epoch, 0.1, 1.0)
        return warm_lr * decay

    return sched


def cosine(peak_lr: float, warmup_steps: int, total_steps: int,
           final_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return sched
