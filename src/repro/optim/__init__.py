"""Optimizers and schedules."""
from .optimizers import (OptState, adamw, apply_updates, clip_by_global_norm,
                         sgd)
from .schedules import constant, cosine, goyal_warmup_step_decay

__all__ = ["OptState", "adamw", "apply_updates", "clip_by_global_norm", "sgd",
           "constant", "cosine", "goyal_warmup_step_decay"]
