"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

The four assigned shapes:
  train_4k     seq=4096    global_batch=256   (training       -> train_step)
  prefill_32k  seq=32768   global_batch=32    (prefill        -> prefill_step)
  decode_32k   seq=32768   global_batch=128   (decode         -> serve_step)
  long_500k    seq=524288  global_batch=1     (long decode    -> serve_step,
                                               sub-quadratic carve-out)

``input_specs`` mirrors the shannon/kernels pattern: weak-type-correct,
shardable ShapeDtypeStructs, no device allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_for(name: str) -> InputShape:
    return SHAPES[name]


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config adaptation: long_500k forces the sub-quadratic
    sliding-window variant on attention blocks (SSM/RG-LRU are already
    sub-quadratic and unaffected)."""
    if shape.name == "long_500k":
        return cfg.windowed()
    return cfg


def _tok_dtype():
    return jnp.int32


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((B, S), _tok_dtype())
    else:  # stubbed frontend: precomputed frame/patch embeddings
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype))
    if cfg.num_codebooks > 1:
        labels = jax.ShapeDtypeStruct((B, S, cfg.num_codebooks), _tok_dtype())
    else:
        labels = jax.ShapeDtypeStruct((B, S), _tok_dtype())
    return {"inputs": inputs, "labels": labels}


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """One new token against a seq_len-deep cache."""
    B = shape.global_batch
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((B, 1), _tok_dtype())
    else:
        inputs = jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype))
    return {"inputs": inputs,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: InputShape, dtype=None) -> list:
    """ShapeDtypeStructs of the decode cache (built via eval_shape — no
    allocation)."""
    from .models.transformer import Model
    model = Model(cfg)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = shape_for(shape_name)
    cfg = adapt_config(cfg, shape)
    if shape.kind in ("train", "prefill"):
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
