from .hlo_cost import HloCost, cost_from_hlo
from .metrics import MetricsRegistry, parse_exposition
from .roofline import (RooflineReport, collective_bytes_from_hlo,
                       model_flops, roofline_terms)
from .tracing import SpanTracer, load_trace, validate_trace

__all__ = ["HloCost", "cost_from_hlo", "MetricsRegistry",
           "parse_exposition", "RooflineReport",
           "collective_bytes_from_hlo", "model_flops", "roofline_terms",
           "SpanTracer", "load_trace", "validate_trace"]
