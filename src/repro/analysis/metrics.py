"""Host-side metrics registry with Prometheus-style text exposition
(DESIGN.md §15).

The span tracer (``analysis/tracing.py``) answers "when did the host do
what"; this registry answers "how much, in total" — monotonic counters,
point-in-time gauges, and bucketed histograms, labeled Prometheus-style:

    reg = MetricsRegistry()
    reg.counter("fleet_requests_total", "requests admitted",
                labels={"fleet": "ring"}).inc()
    reg.histogram("fleet_ttft_rounds", "time to first token",
                  buckets=(1, 2, 4, 8)).observe(3.0)
    text = reg.exposition()     # Prometheus text format 0.0.4
    snap = reg.snapshot()       # JSON-able dict for BENCH_*.json

Stdlib-only, no server: benchmarks embed ``snapshot()`` in their JSON
artifacts and write ``exposition()`` next to them, so any Prometheus
scraper (or a human with grep) can read fleet health without the repo.
"""
from __future__ import annotations

import math
from typing import Iterable


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def _validate_name(name: str) -> str:
    if not name or not all(c.isalnum() or c in "_:" for c in name):
        raise ValueError(f"invalid metric name {name!r} (want "
                         "[a-zA-Z0-9_:]+)")
    if name[0].isdigit():
        raise ValueError(f"metric name {name!r} must not start with a "
                         "digit")
    return name


def _label_str(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotonic counter (one labeled child of a family)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        self.value += amount


class Gauge:
    """Point-in-time value."""

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper edge; +Inf is implicit)."""

    def __init__(self, buckets: Iterable[float]):
        edges = tuple(float(b) for b in buckets)
        if list(edges) != sorted(set(edges)) or not edges:
            raise ValueError("histogram buckets must be strictly "
                             f"increasing and non-empty, got {edges}")
        self.edges = edges
        self.bucket_counts = [0] * (len(edges) + 1)  # last = +Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        for i, edge in enumerate(self.edges):
            if v <= edge:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def cumulative(self) -> list[int]:
        out, run = [], 0
        for c in self.bucket_counts:
            run += c
            out.append(run)
        return out


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Families of labeled counters/gauges/histograms."""

    def __init__(self):
        # name -> (type, help, {label_str: child})
        self._families: dict[str, tuple[str, str, dict]] = {}

    def _family(self, kind: str, name: str, help_: str):
        _validate_name(name)
        fam = self._families.get(name)
        if fam is None:
            fam = (kind, help_, {})
            self._families[name] = fam
        elif fam[0] != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam[0]}, not {kind}")
        return fam

    def counter(self, name: str, help_: str = "",
                labels: dict | None = None) -> Counter:
        fam = self._family("counter", name, help_)
        key = _label_str({k: str(v) for k, v in (labels or {}).items()})
        return fam[2].setdefault(key, Counter())

    def gauge(self, name: str, help_: str = "",
              labels: dict | None = None) -> Gauge:
        fam = self._family("gauge", name, help_)
        key = _label_str({k: str(v) for k, v in (labels or {}).items()})
        return fam[2].setdefault(key, Gauge())

    def histogram(self, name: str, help_: str = "",
                  buckets: Iterable[float] = (0.005, 0.05, 0.5, 5.0),
                  labels: dict | None = None) -> Histogram:
        fam = self._family("histogram", name, help_)
        key = _label_str({k: str(v) for k, v in (labels or {}).items()})
        return fam[2].setdefault(key, Histogram(buckets))

    # ---------------------------------------------------------- exposition
    def exposition(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        for name, (kind, help_, children) in sorted(
                self._families.items()):
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for key, child in sorted(children.items()):
                if kind in ("counter", "gauge"):
                    lines.append(f"{name}{key} "
                                 f"{_fmt_value(child.value)}")
                    continue
                # histogram: cumulative le-buckets + _sum + _count
                cum = child.cumulative()
                base = key[1:-1] if key else ""
                for edge, c in zip(child.edges + (math.inf,), cum):
                    le = f'le="{_fmt_value(edge)}"'
                    lab = "{" + (base + "," if base else "") + le + "}"
                    lines.append(f"{name}_bucket{lab} {c}")
                lines.append(f"{name}_sum{key} {_fmt_value(child.sum)}")
                lines.append(f"{name}_count{key} {child.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """JSON-able dump (embedded in ``BENCH_*.json`` artifacts)."""
        out: dict = {}
        for name, (kind, help_, children) in self._families.items():
            fam: dict = {"type": kind, "help": help_, "series": {}}
            for key, child in children.items():
                if kind in ("counter", "gauge"):
                    fam["series"][key or "{}"] = child.value
                else:
                    fam["series"][key or "{}"] = {
                        "count": child.count, "sum": child.sum,
                        "buckets": dict(zip(
                            [_fmt_value(e) for e in child.edges]
                            + ["+Inf"], child.cumulative()))}
            out[name] = fam
        return out


def parse_exposition(text: str) -> dict:
    """Minimal parser for the text format (the round-trip test gate):
    returns ``{name: {label_str: value}}`` for sample lines, skipping
    comments.  Raises ``ValueError`` on malformed lines."""
    out: dict[str, dict[str, float]] = {}
    for ln, line in enumerate(text.splitlines()):
        if not line.strip() or line.startswith("#"):
            continue
        try:
            metric, value = line.rsplit(" ", 1)
        except ValueError:
            raise ValueError(f"line {ln}: no value in {line!r}") from None
        if "{" in metric:
            name, rest = metric.split("{", 1)
            if not rest.endswith("}"):
                raise ValueError(f"line {ln}: unterminated labels in "
                                 f"{line!r}")
            labels = "{" + rest
        else:
            name, labels = metric, ""
        _validate_name(name)
        v = float(value) if value not in ("+Inf", "-Inf") \
            else math.inf * (1 if value == "+Inf" else -1)
        out.setdefault(name, {})[labels] = v
    return out
