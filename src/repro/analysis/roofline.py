"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

# TPU v5e per-chip constants (launch/mesh.py mirrors these)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512,128]{2,1,0} all-gather(" — shape of the RESULT
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum result-operand sizes per collective kind (bytes, per-program =
    per-device in SPMD)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        # '-start' ops are counted; their '-done' twins produce no new bytes
        if m.group(0).find("-done(") >= 0:
            continue
        out[kind] += _shape_bytes(dtype, dims)
    return out


def model_flops(param_count: int, active_param_count: int, tokens: int,
                kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) with N = active params for MoE; decode
    steps use 2*N_active per token (forward only)."""
    n = active_param_count or param_count
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per-device FLOPs from cost_analysis
    hlo_bytes: float          # per-device bytes accessed
    collective_bytes: float   # per-device collective bytes (sum over kinds)
    collective_detail: dict
    model_flops_total: float  # analytic 6ND (global)
    peak_memory_per_device: float

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "collective_detail": self.collective_detail,
            "model_flops_total": self.model_flops_total,
            "peak_memory_per_device": self.peak_memory_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   cost: dict, hlo_text: str, model_flops_total: float,
                   peak_memory: float) -> RooflineReport:
    coll = collective_bytes_from_hlo(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(sum(coll.values())),
        collective_detail=coll,
        model_flops_total=model_flops_total,
        peak_memory_per_device=peak_memory,
    )
