"""Trip-count-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, regardless of
trip count — with scan-over-layers (and microbatch accumulation scans) that
undercounts FLOPs, bytes and collective traffic by the loop trip counts.
This module re-derives the three roofline inputs from ``compiled.as_text()``:

  * builds the computation call graph (while bodies, fusions, calls),
  * recovers each while loop's trip count from the comparison constant in its
    condition computation,
  * counts dot/convolution FLOPs from shapes + contracting dims,
  * counts HBM write traffic as the result bytes of top-level (post-fusion)
    ops,
  * counts collective bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),

multiplying everything by the product of enclosing trip counts.
Validated against unrolled references in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|calls|true_computation|false_computation)"
    r"=\{?%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DDN_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DDN_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), m.group(2)


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 0)


def _all_result_shapes(defn: str):
    """Result type(s): possibly a tuple '(f32[..], bf16[..])' before op name."""
    # the result type is everything before the first opcode word; just grab
    # every shape until the opening '(' of the operand list after the opcode.
    # Simpler: take shapes appearing before the first alphabetic opcode token
    # that is followed by '('.  Practical approach: shapes in the text up to
    # the first ') ' or the opcode — we take shapes before ' op_name('.
    m = re.match(r"^\(?((?:[a-z][a-z0-9]*\[[0-9,]*\][^\s,()]*,?\s*)+)\)?\s+[\w\-]+\(",
                 defn)
    if not m:
        s = _first_shape(defn)
        return [s] if s else []
    return _SHAPE_RE.findall(m.group(1))


@dataclasses.dataclass
class Computation:
    name: str
    flops: float = 0.0
    write_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    # (called_comp, kind) kind in {"while", "call", "fusion", "cond"}
    calls: list = dataclasses.field(default_factory=list)
    while_trip: dict = dataclasses.field(default_factory=dict)  # body -> trips
    max_cond_const: int = 1


def parse_hlo(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    shapes: dict[str, tuple] = {}   # %var -> (dtype, dims) last definition
    cur: Computation | None = None
    entry_name: str | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            cur = comps.setdefault(hdr.group(1), Computation(hdr.group(1)))
            if line.strip().startswith("ENTRY"):
                entry_name = hdr.group(1)
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        var, defn = m.group(1), m.group(2)
        rshape = _first_shape(defn)
        if rshape:
            shapes[var] = rshape

        opcode_m = re.search(r"\]\S*\s+([\w\-]+)\(", defn)
        opcode = opcode_m.group(1) if opcode_m else ""

        # ---- call graph edges
        for bm in _BRANCHES_RE.finditer(defn):
            for name in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                # count each branch once (upper bound: all branches "execute")
                cur.calls.append((name, "call", var, defn))
        defn_nobranch = _BRANCHES_RE.sub("", defn)
        for cm in _CALLED_RE.finditer(defn_nobranch):
            callee = cm.group(1)
            if "while(" in defn:
                kind = "while"
            elif opcode == "fusion":
                kind = "fusion"
            elif "condition=" in defn and callee in defn.split("condition=")[1][:80]:
                kind = "cond"
            else:
                kind = "call"
            cur.calls.append((callee, kind, var, defn))

        # ---- constants (for trip counts in condition computations)
        cc = re.match(r"^s(?:32|64)\[\]\s.*constant\((\d+)\)", defn)
        if cc:
            cur.max_cond_const = max(cur.max_cond_const, int(cc.group(1)))
        else:
            cc2 = re.search(r"constant\((\d+)\)", defn)
            if cc2 and defn.startswith(("s32[]", "s64[]", "u32[]", "u64[]")):
                cur.max_cond_const = max(cur.max_cond_const, int(cc2.group(1)))

        # ---- flops: dot / convolution
        if opcode in ("dot", "convolution") and rshape:
            out_elems = _shape_elems(rshape[1])
            # contracted size from lhs operand shape + contracting dims
            ops = re.findall(r"%([\w\.\-]+)", defn.split(opcode + "(", 1)[1])
            contracted = 1
            if opcode == "dot":
                cm_ = _DDN_CONTRACT_RE.search(defn)
                if cm_ and ops:
                    lhs = shapes.get(ops[0])
                    if lhs:
                        dims = ([int(d) for d in lhs[1].split(",")]
                                if lhs[1] else [])
                        for ci in (cm_.group(1).split(",")
                                   if cm_.group(1) else []):
                            i = int(ci)
                            if i < len(dims):
                                contracted *= dims[i]
            else:  # convolution: window size from kernel operand
                if len(ops) >= 2:
                    ker = shapes.get(ops[1])
                    if ker:
                        dims = ([int(d) for d in ker[1].split(",")]
                                if ker[1] else [])
                        # HWIO kernel: all dims except O contract per output
                        contracted = max(1, _shape_elems(ker[1])
                                         // (dims[-1] if dims else 1))
            cur.flops += 2.0 * out_elems * contracted

        # ---- write traffic: result bytes of top-level ops (post-fusion)
        if rshape and opcode not in ("parameter", "constant", "tuple",
                                     "get-tuple-element", "bitcast"):
            if opcode == "dynamic-update-slice":
                # in-place on real hardware (buffers alias): count the
                # UPDATE operand, not the full rewritten buffer — decode KV
                # caches would otherwise count as rewritten every token
                ops_ = re.findall(r"%([\w\.\-]+)",
                                  defn.split("dynamic-update-slice(", 1)[1])
                upd = shapes.get(ops_[1]) if len(ops_) > 1 else None
                cur.write_bytes += (_shape_bytes(*upd) if upd
                                    else _shape_bytes(*rshape))
            else:
                for (dt, dm) in _all_result_shapes(defn):
                    cur.write_bytes += _shape_bytes(dt, dm)

        # ---- collectives
        for kind in COLLECTIVE_KINDS:
            if re.search(rf"\s{kind}(?:-start)?\(", defn) and rshape:
                b = sum(_shape_bytes(dt, dm)
                        for (dt, dm) in _all_result_shapes(defn))
                cur.collective_bytes += b
                cur.collective_detail[kind] = (
                    cur.collective_detail.get(kind, 0) + b)
                break
    return comps, entry_name


@dataclasses.dataclass(frozen=True)
class HloCost:
    flops: float
    write_bytes: float
    collective_bytes: float
    collective_detail: dict


def cost_from_hlo(text: str, entry: str | None = None) -> HloCost:
    comps, entry_name = parse_hlo(text)
    if not comps:
        return HloCost(0.0, 0.0, 0.0, {})
    entry = entry or entry_name
    if entry is None:
        # fallback: uncalled computation with the largest reachable flops
        called = {c for comp in comps.values() for (c, *_rest) in comp.calls}
        entries = [n for n in comps if n not in called] or list(comps)
        entry = max(entries, key=lambda n: comps[n].flops)

    detail_total: dict[str, float] = defaultdict(float)

    def walk(name: str, mult: float, in_fusion: bool
             ) -> tuple[float, float, float]:
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0)
        f = comp.flops * mult
        # fusion internals don't write to HBM — only the fusion result does,
        # and that is already counted at the call site computation
        w = 0.0 if in_fusion else comp.write_bytes * mult
        c = comp.collective_bytes * mult
        for k, v in comp.collective_detail.items():
            detail_total[k] += v * mult
        for callee, kind, _var, defn in comp.calls:
            m2 = mult
            if kind == "while":
                cond_m = re.search(r"condition=\{?%?([\w\.\-]+)", defn)
                if cond_m and callee == cond_m.group(1):
                    continue  # skip the (negligible) condition computation
                # prefer XLA's own annotation, fall back to the condition const
                tc = re.search(r'"known_trip_count":\{"n":"(\d+)"', defn)
                if tc:
                    trips = int(tc.group(1))
                elif cond_m and cond_m.group(1) in comps:
                    trips = comps[cond_m.group(1)].max_cond_const
                else:
                    trips = 1
                m2 = mult * max(trips, 1)
            df, dw, dc = walk(callee, m2,
                              in_fusion or kind in ("fusion", "call"))
            f, w, c = f + df, w + dw, c + dc
        return f, w, c

    f, w, c = walk(entry, 1.0, False)
    return HloCost(f, w, c, dict(detail_total))
