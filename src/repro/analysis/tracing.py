"""Host-side span tracer: Chrome-trace-event JSON (DESIGN.md §15).

The compiled side of the flight recorder (``core/telemetry.py``) records
WHAT the replay did, per round, as data on the scan carry.  This module
records WHEN the host did things around those replays: jit traces,
dispatches, fleet rounds, prefill/decode steps, drain — as *spans* in the
Chrome trace event format, loadable directly into Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.

Event vocabulary (the subset of the trace-event spec we emit):

  * ``ph: "X"`` — complete spans (name, ts, dur in microseconds);
  * ``ph: "C"`` — counter samples (queue depth, slot occupancy,
    consensus), rendered as stacked track charts;
  * ``ph: "i"`` — instant events (churn kills, quarantine convictions);
  * ``ph: "M"`` — metadata (process/thread names).

One ``SpanTracer`` is one trace file: ``{"traceEvents": [...]}`` plus a
top-level ``metadata`` dict for run parameters.  All timestamps come from
one ``time.perf_counter`` origin captured at construction, so spans from
different subsystems (fleet loop, benchmark harness) line up on one
timeline.  ``validate_trace`` is the schema gate used by the tests and
the CI trace-smoke step.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any

# trace-event phases we emit (and validate_trace accepts)
_PHASES = {"X", "C", "i", "M"}


class SpanTracer:
    """Collects Chrome trace events; write once at the end of a run.

    process/thread ids are logical labels (pid = subsystem, tid = lane),
    not OS ids — Perfetto renders each (pid, tid) pair as its own track.
    """

    def __init__(self, process: str = "repro", *,
                 metadata: dict | None = None):
        self._origin = time.perf_counter()
        self.events: list[dict] = []
        self.metadata: dict = dict(metadata or {})
        self._pids: dict[str, int] = {}
        self._tids: dict[tuple[int, str], int] = {}
        self._root = process
        self.process(process)

    # ------------------------------------------------------------- identity
    def process(self, name: str) -> int:
        """Logical process id for ``name`` (created + announced once)."""
        if name not in self._pids:
            pid = len(self._pids) + 1
            self._pids[name] = pid
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0,
                                "args": {"name": name}})
        return self._pids[name]

    def thread(self, pid: int, name: str) -> int:
        """Logical thread id for a lane within process ``pid``."""
        key = (pid, name)
        if key not in self._tids:
            tid = sum(1 for p, _ in self._tids if p == pid) + 1
            self._tids[key] = tid
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": name}})
        return self._tids[key]

    # ---------------------------------------------------------------- clock
    def now_us(self) -> float:
        return (time.perf_counter() - self._origin) * 1e6

    # --------------------------------------------------------------- events
    @contextmanager
    def span(self, name: str, *, process: str | None = None,
             lane: str = "main", args: dict | None = None):
        """Context manager emitting one complete ("X") span.  ``process``
        defaults to the tracer's root process (every emitter below
        does)."""
        pid = self.process(process or self._root)
        tid = self.thread(pid, lane)
        t0 = self.now_us()
        try:
            yield self
        finally:
            self.events.append({
                "ph": "X", "name": name, "pid": pid, "tid": tid,
                "ts": t0, "dur": self.now_us() - t0,
                "args": _jsonable(args or {})})

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 process: str | None = None, lane: str = "main",
                 args: dict | None = None) -> None:
        """An explicit-timestamp "X" span (for durations measured
        elsewhere, e.g. ``_timeit`` results)."""
        pid = self.process(process or self._root)
        tid = self.thread(pid, lane)
        self.events.append({"ph": "X", "name": name, "pid": pid,
                            "tid": tid, "ts": float(ts_us),
                            "dur": float(dur_us),
                            "args": _jsonable(args or {})})

    def instant(self, name: str, *, process: str | None = None,
                lane: str = "main", args: dict | None = None) -> None:
        """A point-in-time ("i") event, thread-scoped."""
        pid = self.process(process or self._root)
        tid = self.thread(pid, lane)
        self.events.append({"ph": "i", "name": name, "pid": pid,
                            "tid": tid, "ts": self.now_us(), "s": "t",
                            "args": _jsonable(args or {})})

    def counter(self, name: str, values: dict, *,
                process: str | None = None) -> None:
        """A counter ("C") sample: ``values`` maps series name -> number
        (one multi-series counter track per ``name``)."""
        pid = self.process(process or self._root)
        self.events.append({"ph": "C", "name": name, "pid": pid, "tid": 0,
                            "ts": self.now_us(),
                            "args": {k: float(v) for k, v in
                                     values.items()}})

    # ----------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "metadata": _jsonable(self.metadata)}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path


def _jsonable(obj: Any) -> Any:
    """Coerce numpy/jax scalars and arrays into plain JSON types."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "item") and getattr(obj, "ndim", None) in (0, None):
        try:
            return obj.item()
        except Exception:
            return str(obj)
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)


# ------------------------------------------------------------------ schema

def validate_trace(obj: dict) -> None:
    """Raise ``ValueError`` unless ``obj`` is a loadable Chrome trace.

    The golden-schema gate for every ``TRACE_*.json`` artifact: object
    format with a ``traceEvents`` list; every event carries a known
    phase, a name, integer pid/tid; timed phases carry numeric ``ts``
    (and ``dur`` for "X"); args (when present) are JSON objects.
    """
    if not isinstance(obj, dict):
        raise ValueError(f"trace must be a JSON object, got "
                         f"{type(obj).__name__}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace must carry a 'traceEvents' list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"traceEvents[{i}]: unknown phase {ph!r} "
                             f"(expected one of {sorted(_PHASES)})")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"traceEvents[{i}]: missing/empty name")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                raise ValueError(f"traceEvents[{i}]: {field} must be an "
                                 f"int, got {ev.get(field)!r}")
        if ph in ("X", "C", "i"):
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"traceEvents[{i}]: ts must be a number")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"traceEvents[{i}]: 'X' span needs a "
                             "numeric dur")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float))
                    for v in args.values()):
                raise ValueError(f"traceEvents[{i}]: 'C' sample needs a "
                                 "non-empty numeric args dict")
        elif "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"traceEvents[{i}]: args must be an object")


def load_trace(path: str) -> dict:
    """Read + validate one ``TRACE_*.json`` artifact."""
    with open(path) as f:
        obj = json.load(f)
    validate_trace(obj)
    return obj
