"""Serving example: batched greedy decoding with KV caches (full + sliding
window), demonstrating the serve_step used by the decode dry-run shapes.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import Model

for windowed in (False, True):
    cfg = get_config("qwen3-0.6b", reduced=True)
    if windowed:
        cfg = cfg.windowed(16)  # long_500k-style ring-buffer cache
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompts, gen=24)
    tag = "window-16 ring cache" if windowed else "full KV cache     "
    print(f"{tag}: {4*24} tokens in {time.time()-t0:.1f}s; "
          f"sample {jax.device_get(out[0, -8:]).tolist()}")
