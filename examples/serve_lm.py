"""Quickstart: a gossip-serving fleet — 8 decode replicas on a lossy ring
that never stop averaging, surviving a mid-serve churn kill.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs.nano_lm import reduced
from repro.core import (Algorithm, ChannelModel, DelayProcess, PhaseSwitch,
                        ServeLoad, World, ring_graph)
from repro.launch.fleet import GossipFleet
from repro.models import Model

model = Model(reduced())
params = model.init(jax.random.PRNGKey(0))

world = World(
    topology=ring_graph(8),
    algorithm=Algorithm("a2cid2"),
    channel=ChannelModel(delay=DelayProcess(horizon=2, prob=0.3),
                         drop_prob=0.1),                   # stale + lossy links
    faults=(PhaseSwitch(20, active=(True,) * 7 + (False,)),),  # kill one replica
    serve=ServeLoad(rate=1.0, prompt_len=(3, 6), gen_len=(4, 10)),
)

fleet = GossipFleet(model, params, world, max_batch=4, max_len=24,
                    drift="perturb", drift_scale=0.02)
rep = fleet.run(rounds=60, seed=0)
s = rep.summary()
print(f"fleet: {s['completed']}/{s['requests_total']} requests, "
      f"{s['tokens_per_second']:.0f} tok/s, p95 latency {s['latency_p95']:.1f} "
      f"rounds, consensus distance {s['consensus_final']:.2f}")
print(f"churn recovery: replica killed at round 20 — lost {s['lost']}, "
      f"re-admitted {s['restarted']} in-flight requests to survivors")
