"""End-to-end driver: decentralized training of the ~100M-class nano-lm with
8 asynchronous gossip workers for a few hundred rounds, comparing the
asynchronous baseline against A2CiD2 on the ring graph.

Reduced-scale by default so it runs on CPU in a few minutes; pass --full for
the ~100M configuration and more rounds.

    PYTHONPATH=src python examples/lm_decentralized.py --rounds 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Algorithm, Simulator, World, ring_graph
from repro.data import LMTaskStream
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("nano-lm", reduced=not args.full)
    model = Model(cfg)
    stream = LMTaskStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          batch_size=args.batch_size, concentration=0.15)

    def grad_fn(params, key, wid):
        batch = stream.sample(jax.random.fold_in(key, wid))
        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss
        return jax.value_and_grad(loss_fn)(params)

    graph = ring_graph(args.workers)
    # coupled-clock algorithms compile the identical schedule; declare the
    # zoo arms as Worlds and reuse one compile
    arms = {"adpsgd": World(topology=graph, algorithm=Algorithm("adpsgd")),
            "a2cid2": World(topology=graph, algorithm=Algorithm("a2cid2"))}
    sched = arms["a2cid2"].compile(args.rounds, seed=args.seed)
    params0 = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params0))
    print(f"nano-lm: {n_params/1e6:.1f}M params, {args.workers} workers, "
          f"ring graph, bayes CE {stream.bayes_ce():.3f}")

    for kind, world in arms.items():
        accel = kind == "a2cid2"
        sim = Simulator(grad_fn, world.algorithm_params(), gamma=0.05)
        state = sim.init(params0, args.workers, jax.random.PRNGKey(1))
        t0 = time.time()
        state, trace = sim.run_schedule(state, sched)
        tag = "A2CiD2  " if accel else "baseline"
        print(f"{tag}: loss {float(trace.loss[0]):.3f} -> "
              f"{float(jnp.mean(trace.loss[-10:])):.3f}   "
              f"consensus {float(jnp.mean(trace.consensus[-10:])):.2e}   "
              f"({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
