"""Quickstart: A2CiD2 in 60 lines — decentralized optimization of a
heterogeneous quadratic on a ring, accelerated vs baseline, then the same
world made hostile: straggler workers and a mid-run topology switch with a
churn window (the scenario engine, DESIGN.md §8).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Simulator, TopologyPhase, TopologySchedule,
                        hypercube_graph, make_schedule,
                        make_topology_schedule, params_from_graph,
                        ring_graph, worker_mean)

N_WORKERS, DIM, ROUNDS = 16, 64, 300

# each worker i minimizes f_i(x) = ||x - b_i||^2 / 2; the consensus optimum
# is mean(b) — exactly the setting of the paper's theory (Sec 3.2)
b = jax.random.normal(jax.random.PRNGKey(1), (N_WORKERS, DIM))


def grad_fn(x, key, worker_id):
    noise = 0.05 * jax.random.normal(key, x.shape)
    return 0.5 * jnp.sum((x - b[worker_id]) ** 2), (x - b[worker_id]) + noise


graph = ring_graph(N_WORKERS)
print(f"ring graph: chi1={graph.chi1():.1f} chi2={graph.chi2():.2f} "
      f"(A2CiD2 accelerates chi1 -> sqrt(chi1*chi2)="
      f"{(graph.chi1()*graph.chi2())**0.5:.1f})")

schedule = make_schedule(graph, rounds=ROUNDS, comms_per_grad=1.0, seed=0)
for accelerated in (False, True):
    acid = params_from_graph(graph, accelerated=accelerated)
    sim = Simulator(grad_fn, acid, gamma=0.05)
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_schedule(state, schedule)
    err = float(jnp.sum((worker_mean(state.x) - jnp.mean(b, 0)) ** 2))
    name = "A2CiD2  " if accelerated else "baseline"
    print(f"{name}: consensus distance {float(trace.consensus[-1]):.3f}  "
          f"distance to optimum {err:.2e}")

# -- the same ring made hostile: odd workers compute gradients at 1/4 rate,
#    two workers drop out mid-run, and the survivors switch to a hypercube
print("\nheterogeneous world: stragglers + churn + ring->hypercube switch")
stragglers = np.where(np.arange(N_WORKERS) % 2 == 0, 1.0, 0.25)
active = np.ones(N_WORKERS, bool)
active[:2] = False
world = TopologySchedule((
    TopologyPhase(graph, ROUNDS // 3),                        # calm ring
    TopologyPhase(graph, ROUNDS // 3, tuple(active)),         # churn window
    TopologyPhase(hypercube_graph(4), ROUNDS // 3),           # rewire + rejoin
))
hostile = make_topology_schedule(world, comms_per_grad=1.0, seed=0,
                                 grad_rates=stragglers)
for accelerated in (False, True):
    acid = params_from_graph(graph, accelerated=accelerated)
    sim = Simulator(grad_fn, acid, gamma=0.05)
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_schedule(state, hostile)
    name = "A2CiD2  " if accelerated else "baseline"
    print(f"{name}: consensus distance {float(trace.consensus[-1]):.3f}  "
          f"(per-phase chi1: "
          f"{', '.join(f'{c1:.1f}' for c1, _ in world.phase_chis())})")
