"""Quickstart: A2CiD2 in 100 lines — decentralized optimization of a
heterogeneous quadratic on a ring, accelerated vs baseline; the same world
made hostile (stragglers, churn, a mid-run topology switch), described
declaratively with the World API (DESIGN.md §9); a LOSSY ring —
stale partner reads plus two Byzantine edges (DESIGN.md §10) — replayed
with and without the robust trimmed-aggregation defense; the SELF-HEALING
version of that defense (adaptive tau + edge quarantine, DESIGN.md §12)
against an attack the static trim cannot see; and a whole SWEEP of worlds
replayed as one batched scan (DESIGN.md §11).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveDefense, ByzantineEdges, ChannelModel,
                        DelayProcess, PhaseSwitch, Simulator, WorkerModel,
                        World, WorldSweep, hypercube_graph,
                        params_from_graph, ring_graph, worker_mean)

N_WORKERS, DIM, ROUNDS = 16, 64, 300

# each worker i minimizes f_i(x) = ||x - b_i||^2 / 2; the consensus optimum
# is mean(b) — exactly the setting of the paper's theory (Sec 3.2)
b = jax.random.normal(jax.random.PRNGKey(1), (N_WORKERS, DIM))


def grad_fn(x, key, worker_id):
    noise = 0.05 * jax.random.normal(key, x.shape)
    return 0.5 * jnp.sum((x - b[worker_id]) ** 2), (x - b[worker_id]) + noise


graph = ring_graph(N_WORKERS)
print(f"ring graph: chi1={graph.chi1():.1f} chi2={graph.chi2():.2f} "
      f"(A2CiD2 accelerates chi1 -> sqrt(chi1*chi2)="
      f"{(graph.chi1()*graph.chi2())**0.5:.1f})")

calm = World(topology=graph)
for accelerated in (False, True):
    acid = params_from_graph(graph, accelerated=accelerated)
    sim = Simulator(grad_fn, acid, gamma=0.05)
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_world(state, calm, ROUNDS, seed=0)
    err = float(jnp.sum((worker_mean(state.x) - jnp.mean(b, 0)) ** 2))
    name = "A2CiD2  " if accelerated else "baseline"
    print(f"{name}: consensus distance {float(trace.consensus[-1]):.3f}  "
          f"distance to optimum {err:.2e}")

# -- the same ring made hostile, declared as a World: odd workers compute
#    gradients at 1/4 rate, two workers drop out mid-run, and the survivors
#    switch to a hypercube.  The description is serializable (to_json) and
#    compiles to ONE event schedule both replay paths consume unchanged.
print("\nheterogeneous world: stragglers + churn + ring->hypercube switch")
stragglers = np.where(np.arange(N_WORKERS) % 2 == 0, 1.0, 0.25)
active = np.ones(N_WORKERS, bool)
active[:2] = False
world = World(
    topology=graph,                                           # calm ring
    workers=WorkerModel(grad_rates=stragglers),
    faults=(PhaseSwitch(ROUNDS // 3, active=tuple(active)),   # churn window
            PhaseSwitch(2 * (ROUNDS // 3),
                        topology=hypercube_graph(4))),        # rewire+rejoin
)
hostile = world.compile(ROUNDS, seed=0)
phases = world.phase_plan(ROUNDS)
for accelerated in (False, True):
    acid = params_from_graph(graph, accelerated=accelerated)
    sim = Simulator(grad_fn, acid, gamma=0.05)
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_schedule(state, hostile)
    name = "A2CiD2  " if accelerated else "baseline"
    print(f"{name}: consensus distance {float(trace.consensus[-1]):.3f}  "
          f"(per-phase chi1: "
          f"{', '.join(f'{c1:.1f}' for c1, _ in phases.phase_chis())})")

# -- the same ring over a LOSSY channel: every partner read is a stale
#    snapshot (up to 3 rounds old, served from the engine's ring buffer),
#    2% of messages are dropped outright, and two edges are Byzantine — a
#    compromised link injecting garbage on half its exchanges.  The channel
#    is part of the declarative World; the defense (norm-trim robust
#    aggregation: reject any p2p delta with ||m|| > tau) is a replay knob.
print("\nlossy ring: stale reads + drops + 2 Byzantine edges")
lossy = World(
    topology=graph,
    channel=ChannelModel(
        delay=DelayProcess(horizon=3, prob=0.5),
        adversary=ByzantineEdges((graph.edges[0], graph.edges[8]),
                                 mode="scale", scale=1e3, prob=0.5),
        drop_prob=0.02,
    ),
)
acid = params_from_graph(graph, accelerated=True)
for robust in (False, True):
    sim = Simulator(grad_fn, acid, gamma=0.05,
                    robust_clip=5.0 if robust else None, robust_rule="trim")
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_world(state, lossy, ROUNDS, seed=0)
    tail = float(trace.consensus[-1])
    name = "A2CiD2 + trim   " if robust else "A2CiD2 no defense"
    print(f"{name}: consensus distance "
          f"{'DIVERGED' if not np.isfinite(tail) else f'{tail:.3f}'}")

# -- self-healing gossip (DESIGN.md §12): a sign-flip adversary corrupts
#    exchanges at HONEST scale, so the static tau above never fires — the
#    trimmed replay is bitwise the undefended one.  Declaring a defense on
#    the World closes the loop inside the compiled scan: an EMA quantile
#    of admitted delta norms tightens tau to the honest noise floor, and
#    per-edge trust quarantines (then heals) edges that keep violating it.
print("\nself-healing: sign-flip attack at honest scale, adaptive tau")
# shared target, scaled so a flipped exchange has norm ~2||x|| ~ 3 < tau=5
# — under the static threshold's radar, well above the honest noise floor
b_shared = 0.2 * b[0]
flippy = ChannelModel(adversary=ByzantineEdges(
    (graph.edges[0], graph.edges[8]), mode="sign_flip", prob=1.0))


def shared_grad(x, key, worker_id):
    del worker_id
    return (0.5 * jnp.sum((x - b_shared) ** 2),
            (x - b_shared) + 0.05 * jax.random.normal(key, x.shape))


for label, defense in (("static trim    ", None),
                       ("adaptive defense", AdaptiveDefense())):
    world = World(topology=graph, channel=flippy, defense=defense)
    sim = Simulator(shared_grad, acid, gamma=0.05,
                    robust_clip=5.0, robust_rule="trim")
    state = sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
    state, trace = sim.run_world(state, world, ROUNDS, seed=0)
    rej = float(jnp.sum(trace.defense.rejections)) if trace.defense else 0.0
    print(f"{label}: consensus distance {float(trace.consensus[-1]):.4f}  "
          f"(rejected exchanges: {rej:.0f})")

# -- many worlds at once: the paper's claims are sweep-shaped, so sweeps
#    are first-class.  A WorldSweep names a grid declaratively; run_worlds
#    replays the WHOLE grid (x 2 seeds here) in ONE compiled scan — one
#    jit trace, one dispatch — with each world's trace row bit-identical
#    to its serial replay (DESIGN.md §11).
print("\nbatched sweep: comms_per_grad grid x 2 seeds, one compiled scan")
sweep = WorldSweep.over(World(topology=graph), seeds=(0, 1),
                        comms_per_grad=[0.5, 1.0, 2.0])
sim = Simulator(grad_fn, params_from_graph(graph, accelerated=True),
                gamma=0.05)
states = [sim.init(jnp.zeros(DIM), N_WORKERS, jax.random.PRNGKey(2))
          for _ in range(sweep.size)]
_, traces = sim.run_worlds(states, sweep.compile(ROUNDS))
for i, (w, s) in enumerate(sweep.points()):
    print(f"comms/grad={w.comms_per_grad:<4} seed={s}: "
          f"consensus distance {float(traces.consensus[i, -1]):.3f}")
