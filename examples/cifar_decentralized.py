"""The paper's own experiment family at CPU scale: ResNet on CIFAR-like data
with asynchronous decentralized workers (paper Sec 4, Tab 4).

    PYTHONPATH=src python examples/cifar_decentralized.py --rounds 60
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import Algorithm, Simulator, World, build_graph, worker_mean
from repro.data import SyntheticCIFAR
from repro.models.resnet import init_resnet, resnet8_cifar, resnet_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--graph", default="ring")
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resnet8_cifar()
    stream = SyntheticCIFAR(batch_size=args.batch_size, noise=0.5)

    def grad_fn(params, key, wid):
        batch = stream.sample(jax.random.fold_in(key, wid))
        def loss_fn(p):
            loss, _ = resnet_loss(p, cfg, batch)
            return loss
        return jax.value_and_grad(loss_fn)(params)

    graph = build_graph(args.graph, args.workers)
    # both arms are coupled-clock algorithms, so they compile the identical
    # schedule — declare the worlds and reuse one compile
    arms = {"adpsgd": World(topology=graph, algorithm=Algorithm("adpsgd")),
            "a2cid2": World(topology=graph, algorithm=Algorithm("a2cid2"))}
    sched = arms["a2cid2"].compile(args.rounds, seed=args.seed)
    params0 = init_resnet(jax.random.PRNGKey(0), cfg)

    for kind, world in arms.items():
        accel = kind == "a2cid2"
        sim = Simulator(grad_fn, world.algorithm_params(), gamma=0.05)
        state = sim.init(params0, args.workers, jax.random.PRNGKey(1))
        t0 = time.time()
        state, trace = sim.run_schedule(state, sched)
        # evaluate the consensus model
        params = worker_mean(state.x)
        test = stream.sample(jax.random.PRNGKey(123))
        _, metrics = resnet_loss(params, cfg, test)
        tag = "A2CiD2  " if accel else "baseline"
        print(f"{tag} ({args.graph}): loss {float(trace.loss[0]):.3f} -> "
              f"{float(jnp.mean(trace.loss[-5:])):.3f}  "
              f"test acc {float(metrics['acc']):.2f}  ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
